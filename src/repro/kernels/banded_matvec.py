"""Pallas TPU kernel: banded covariance-matrix product (the PIM hot loop).

The paper's distributed ``Cv`` (Sec. 3.4.3) restricted to a banded layout:
``y[i] = sum_k band[k, i] * v[i + k - h]``.  On the device this is the
per-shard inner loop of every power-iteration step, so it is the compute
hot-spot of the paper's algorithm.

Design for TPU (DESIGN.md Sec. 2.3):
* the band is tiled along the feature axis into VMEM blocks of ``block_p``
  columns; the full (small) halo-padded operand vector/matrix stays resident
  in VMEM (p_local + 2h elements — a per-device shard, tens of KB);
* the diagonal loop (2h+1 iterations, h static) is unrolled in the kernel;
  each step is a VPU multiply-add over a ``block_p``-wide slice, which keeps
  the 8x128 vector registers full when block_p is a multiple of 128;
* the matmul variant (``banded_matmul``: V has q columns) is the blocked
  orthogonal-iteration workhorse — q is kept in the minor dimension so each
  multiply-add is an (block_p, q) tile op.

The wrappers in ops.py pad the operand with h zeros per side so the kernel
body needs no bounds checks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["banded_matvec_pallas", "banded_matmul_pallas"]


def _matvec_kernel(band_ref, vpad_ref, out_ref, *, nb: int, block_p: int):
    i = pl.program_id(0)
    base = i * block_p
    acc = jnp.zeros((1, block_p), dtype=jnp.float32)
    for k in range(nb):                       # static unroll over diagonals
        bandk = band_ref[k, :].reshape(1, block_p).astype(jnp.float32)
        vslice = vpad_ref[0, pl.dslice(base + k, block_p)]
        acc = acc + bandk * vslice.reshape(1, block_p).astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def banded_matvec_pallas(band: jnp.ndarray, v_padded: jnp.ndarray,
                         *, block_p: int, interpret: bool = False) -> jnp.ndarray:
    """y (1, p) from band (nb, p) and v_padded (1, p + nb - 1)."""
    nb, p = band.shape
    assert p % block_p == 0, (p, block_p)
    assert v_padded.shape == (1, p + nb - 1)
    grid = (p // block_p,)
    return pl.pallas_call(
        functools.partial(_matvec_kernel, nb=nb, block_p=block_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb, block_p), lambda i: (0, i)),      # band tile
            pl.BlockSpec(v_padded.shape, lambda i: (0, 0)),     # full operand
        ],
        out_specs=pl.BlockSpec((1, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), band.dtype),
        interpret=interpret,
    )(band, v_padded)


def _matmul_kernel(band_ref, vpad_ref, out_ref, *, nb: int, block_p: int):
    i = pl.program_id(0)
    base = i * block_p
    q = out_ref.shape[-1]
    acc = jnp.zeros((block_p, q), dtype=jnp.float32)
    for k in range(nb):
        bandk = band_ref[k, :].reshape(block_p, 1).astype(jnp.float32)
        vtile = vpad_ref[pl.dslice(base + k, block_p), :].astype(jnp.float32)
        acc = acc + bandk * vtile
    out_ref[...] = acc.astype(out_ref.dtype)


def banded_matmul_pallas(band: jnp.ndarray, v_padded: jnp.ndarray,
                         *, block_p: int, interpret: bool = False) -> jnp.ndarray:
    """Y (p, q) from band (nb, p) and v_padded (p + nb - 1, q)."""
    nb, p = band.shape
    q = v_padded.shape[1]
    assert p % block_p == 0, (p, block_p)
    assert v_padded.shape[0] == p + nb - 1
    grid = (p // block_p,)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nb=nb, block_p=block_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb, block_p), lambda i: (0, i)),
            pl.BlockSpec(v_padded.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_p, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, q), band.dtype),
        interpret=interpret,
    )(band, v_padded)
