"""Pallas TPU kernels for the paper's compute hot spots.

banded_matvec  — Cv of the distributed power iteration (Sec. 3.4.3)
cov_update     — streaming banded covariance update (Eq. 10)
pca_project    — PCAg scores / reconstruction (Eq. 5-6)

ops.py holds the jitted wrappers; ref.py the pure-jnp oracles.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
