"""Pallas TPU kernel: streaming banded-covariance update (Eq. 10).

``delta[k, i] = sum_t x[t, i] * x[t, i + k - h]`` — the per-epoch sufficient
statistic update of the paper's Sec. 3.3, batched over a measurement block.
This is a rank-n update restricted to the band: per feature tile it is an
elementwise product of the tile with a shifted view of the halo-padded batch,
reduced over the batch axis (VPU work with an 8-deep sublane reduction).

Tiling: grid = (feature blocks, batch blocks); the batch axis is the inner
grid dimension so the output band tile is revisited consecutively and
accumulated in place (Pallas output-revisiting pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cov_band_update_pallas", "cov_band_update_masked_pallas",
           "cov_band_update_chunk_pallas", "cov_band_update_chunk_masked_pallas"]


def _kernel(x_ref, xpad_ref, out_ref, *, nb: int, block_p: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    base = i * block_p

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)                  # (bn, block_p)
    rows = []
    for k in range(nb):
        xs = xpad_ref[:, pl.dslice(base + k, block_p)].astype(jnp.float32)
        rows.append(jnp.sum(x * xs, axis=0))            # (block_p,)
    out_ref[...] = out_ref[...] + jnp.stack(rows, axis=0).astype(out_ref.dtype)


def cov_band_update_pallas(x: jnp.ndarray, x_padded: jnp.ndarray,
                           *, halfwidth: int, block_p: int, block_n: int,
                           interpret: bool = False) -> jnp.ndarray:
    """delta band (2h+1, p) from x (n, p) and x_padded (n, p + 2h)."""
    n, p = x.shape
    h = halfwidth
    nb = 2 * h + 1
    assert p % block_p == 0 and n % block_n == 0, (n, p, block_n, block_p)
    assert x_padded.shape == (n, p + 2 * h)
    grid = (p // block_p, n // block_n)                 # batch axis innermost
    return pl.pallas_call(
        functools.partial(_kernel, nb=nb, block_p=block_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_p), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, p + 2 * h), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((nb, block_p), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nb, p), jnp.float32),
        interpret=interpret,
    )(x, x_padded)


def _masked_kernel(x_ref, xpad_ref, m_ref, mpad_ref, out_ref,
                   *, nb: int, block_p: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    base = i * block_p

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # the mask multiply is fused into the tile load: a dead sensor (or a
    # dropped reading) contributes an exact 0 to every product it touches
    x = (x_ref[...] * m_ref[...]).astype(jnp.float32)   # (bn, block_p)
    rows = []
    for k in range(nb):
        sl = pl.dslice(base + k, block_p)
        xs = (xpad_ref[:, sl] * mpad_ref[:, sl]).astype(jnp.float32)
        rows.append(jnp.sum(x * xs, axis=0))            # (block_p,)
    out_ref[...] = out_ref[...] + jnp.stack(rows, axis=0).astype(out_ref.dtype)


def cov_band_update_masked_pallas(x: jnp.ndarray, x_padded: jnp.ndarray,
                                  mask: jnp.ndarray, mask_padded: jnp.ndarray,
                                  *, halfwidth: int, block_p: int,
                                  block_n: int,
                                  interpret: bool = False) -> jnp.ndarray:
    """Masked variant: delta[k, i] = sum_t m[t,i] x[t,i] m[t,i'] x[t,i'].

    ``mask`` is an (n, p) 0/1 validity matrix (sensor liveness broadcast over
    the batch, or per-reading measurement dropout); masked entries contribute
    nothing to any band product.  Same tiling as the unmasked kernel — the
    mask rides the existing BlockSpecs, so with an all-ones mask the grid
    schedule (and hence the float accumulation order) is identical, which is
    what makes the differential test in tests/test_faults.py exact.
    """
    n, p = x.shape
    h = halfwidth
    nb = 2 * h + 1
    assert p % block_p == 0 and n % block_n == 0, (n, p, block_n, block_p)
    assert x_padded.shape == (n, p + 2 * h)
    assert mask.shape == (n, p) and mask_padded.shape == (n, p + 2 * h)
    grid = (p // block_p, n // block_n)                 # batch axis innermost
    return pl.pallas_call(
        functools.partial(_masked_kernel, nb=nb, block_p=block_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_p), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, p + 2 * h), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, block_p), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, p + 2 * h), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((nb, block_p), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nb, p), jnp.float32),
        interpret=interpret,
    )(x, x_padded, mask, mask_padded)


def _chunk_kernel(x_ref, xpad_ref, w_ref, out_ref, *, nb: int, block_p: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    base = i * block_p

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # the per-row chunk weight (the round's exponential-forgetting factor
    # gamma^(K-1-t), or 0 for a padded row) is fused into the tile load
    # exactly like the mask multiply; each band product carries its round's
    # weight exactly once (the shifted operand stays unweighted)
    x = x_ref[...].astype(jnp.float32) * w_ref[...].astype(jnp.float32)
    rows = []
    for k in range(nb):
        xs = xpad_ref[:, pl.dslice(base + k, block_p)].astype(jnp.float32)
        rows.append(jnp.sum(x * xs, axis=0))            # (block_p,)
    out_ref[...] = out_ref[...] + jnp.stack(rows, axis=0).astype(out_ref.dtype)


def cov_band_update_chunk_pallas(x: jnp.ndarray, x_padded: jnp.ndarray,
                                 w: jnp.ndarray, *, halfwidth: int,
                                 block_p: int, block_n: int,
                                 interpret: bool = False) -> jnp.ndarray:
    """Multi-round fused band update: one launch folds a whole chunk.

    ``x`` is a chunk of rounds flattened on the row axis, (K*n, p);
    ``w`` (K*n, 1) carries each row's round weight (the exponential-
    forgetting factor of its round within the chunk; 0 for pad rows).
    delta[k, i] = sum_r w[r] * x[r, i] * x[r, i + k - h].

    Same tiling as :func:`cov_band_update_pallas` with the flattened row
    axis as the inner grid dimension: the (2h+1, block_p) accumulator tile
    is revisited in VMEM across the WHOLE chunk and written back to HBM
    once per feature block — one band read-modify-write per chunk instead
    of one per round.  At K=1 with w=1 the grid schedule and float
    accumulation order are identical to the per-round kernel (x * 1.0 is a
    bitwise identity), which is what makes the probe_every=1 differential
    test in tests/test_chunked_streaming.py exact.
    """
    rows, p = x.shape
    h = halfwidth
    nb = 2 * h + 1
    assert p % block_p == 0 and rows % block_n == 0, (rows, p, block_n, block_p)
    assert x_padded.shape == (rows, p + 2 * h)
    assert w.shape == (rows, 1)
    grid = (p // block_p, rows // block_n)              # row axis innermost
    return pl.pallas_call(
        functools.partial(_chunk_kernel, nb=nb, block_p=block_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_p), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, p + 2 * h), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((nb, block_p), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nb, p), jnp.float32),
        interpret=interpret,
    )(x, x_padded, w)


def _chunk_masked_kernel(x_ref, xpad_ref, m_ref, mpad_ref, w_ref, out_ref,
                         *, nb: int, block_p: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    base = i * block_p

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # mask fused like the per-round masked kernel, then the round weight
    # (same load order keeps K=1/w=1 bit-identical to that kernel)
    x = (x_ref[...] * m_ref[...]).astype(jnp.float32) \
        * w_ref[...].astype(jnp.float32)
    rows = []
    for k in range(nb):
        sl = pl.dslice(base + k, block_p)
        xs = (xpad_ref[:, sl] * mpad_ref[:, sl]).astype(jnp.float32)
        rows.append(jnp.sum(x * xs, axis=0))            # (block_p,)
    out_ref[...] = out_ref[...] + jnp.stack(rows, axis=0).astype(out_ref.dtype)


def cov_band_update_chunk_masked_pallas(x: jnp.ndarray, x_padded: jnp.ndarray,
                                        mask: jnp.ndarray,
                                        mask_padded: jnp.ndarray,
                                        w: jnp.ndarray, *, halfwidth: int,
                                        block_p: int, block_n: int,
                                        interpret: bool = False
                                        ) -> jnp.ndarray:
    """Masked chunk variant: delta[k,i] = sum_r w_r m[r,i] x[r,i] m[r,i'] x[r,i'].

    Rows are the flattened (K*n) chunk; ``mask`` carries per-row validity
    (liveness broadcast over the round's epochs, or per-reading dropout)
    and ``w`` the per-row round weights, both fused into the tile loads.
    """
    rows, p = x.shape
    h = halfwidth
    nb = 2 * h + 1
    assert p % block_p == 0 and rows % block_n == 0, (rows, p, block_n, block_p)
    assert x_padded.shape == (rows, p + 2 * h)
    assert mask.shape == (rows, p) and mask_padded.shape == (rows, p + 2 * h)
    assert w.shape == (rows, 1)
    grid = (p // block_p, rows // block_n)              # row axis innermost
    return pl.pallas_call(
        functools.partial(_chunk_masked_kernel, nb=nb, block_p=block_p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_p), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, p + 2 * h), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, block_p), lambda i, j: (j, i)),
            pl.BlockSpec((block_n, p + 2 * h), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((nb, block_p), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((nb, p), jnp.float32),
        interpret=interpret,
    )(x, x_padded, mask, mask_padded, w)
