"""Pallas TPU kernel: one-pass fused streaming chunk update (DESIGN.md
Sec. 14).

The chunked streaming hot loop used to pay up to three HBM passes over the
same flattened (K·n, p) chunk — the banded cov-update
(:mod:`repro.kernels.cov_update`), the ε-supervised compression pass and
the T²/SPE monitoring pass (:mod:`repro.kernels.pca_project`) are separate
``pallas_call``s reading identical tiles.  The paper's whole Sec.-2.4
argument is that ONE aggregation pass per epoch amortizes all per-round
work; Elgamal & Hefeeda (PAPERS.md) show the memory-traffic term dominating
distributed-PCA cost at scale.  This kernel loads each tile of the chunk
into VMEM once and produces, from the same tiles,

* the forgetting-weighted band accumulator
  ``delta[k, i] = Σ_r w_r m[r,i] x[r,i] m[r,i'] x[r,i']`` (the multi-round
  fold of :func:`repro.kernels.cov_update.cov_band_update_chunk_pallas`),
* the compression stage ``Z = ((X − mean)·m) W``, ``X_hat = Z Wᵀ + mean``,
  ``flags = (|X − X_hat| > ε) & m`` (when ``with_compress``),
* the monitoring stage ``T² = Σ_k z_k² inv_λ_k``,
  ``SPE = ‖((X − mean)·m − Z Wᵀ)·m‖²`` (when ``with_monitor``),

collapsing the chunk body from 3 kernel launches to 1.

Tiling: the grid is (feature blocks, row blocks) — EXACTLY the cov chunk
kernel's grid, with the same block specs and the same fold body, so the
band accumulator is produced by the same sequence of loads, multiplies and
row reductions and its fp32 bits are identical to the split kernel's (the
differential guarantee of tests/test_fused_stream.py; XLA re-vectorizes a
reduction when the tile shapes around it change, so structural congruence
is what carries bit-equality, not just the math).  The band output block
has a j-constant index map and is revisited consecutively across the row
sweep of each feature block — the Pallas in-VMEM accumulation pattern.

The stages run once per row block, on the FIRST feature step
(``pl.program_id(0) == 0``), reading the full-width rows back out of the
halo slab (which is resident anyway for the shifted band products) at the
exact unpadded sensor count — a feature-padded chunk (awkward p) must not
change the stage dots' reduction width, or their bits would drift from the
standalone stage kernels.  The stage outputs advance with the row block
and are written only on that first feature step; with more than one
feature block those output blocks are technically revisited (idly) later
in the sweep, which interpret mode carries through untouched — on a real
TPU backend the roofline feature targets (:mod:`repro.launch.tiling`) keep
p inside one feature block for every WSN-scale network, so the idle
revisit never materializes there.

Precision: every tile is cast to fp32 on load and every accumulation runs
in fp32 (``preferred_element_type=jnp.float32``), whatever the operand
dtype — so the optional bf16 mode (the ops wrapper casts the large
operands x/xpad/mask/W to bfloat16 before the call) halves the HBM tile
traffic while the band fold and the stage reductions keep fp32
accumulators.  With fp32 operands the arithmetic (and hence, in interpret
mode, the bits) is identical to the three split kernels: the band part
replicates ``_chunk_masked_kernel`` load-for-load and the stage part
replicates ``_supervised_kernel``/``_monitor_kernel`` op-for-op per
(block_n, p) slab.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_stream_pallas"]


def _fused_kernel(x_ref, xpad_ref, m_ref, mpad_ref, w_ref, basis_ref,
                  mean_ref, invlam_ref, *out_refs,
                  nb: int, p: int, eps: float, with_compress: bool,
                  with_monitor: bool):
    i = pl.program_id(0)                    # feature block (band fold)
    j = pl.program_id(1)                    # row block
    block_p = x_ref.shape[1]
    base = i * block_p
    h = (nb - 1) // 2
    band_ref = out_refs[0]
    z_ref = out_refs[1]
    k_out = 2
    if with_compress:
        xh_ref, flag_ref = out_refs[k_out], out_refs[k_out + 1]
        k_out += 2
    if with_monitor:
        t2_ref, spe_ref = out_refs[k_out], out_refs[k_out + 1]

    @pl.when(j == 0)
    def _init():
        band_ref[...] = jnp.zeros_like(band_ref)

    # --- band fold: line-for-line _chunk_masked_kernel (mask fused into
    # the tile load, then the per-row round weight; shifted operand masked
    # but unweighted, so each product carries its weight once)
    xw = (x_ref[...] * m_ref[...]).astype(jnp.float32) \
        * w_ref[...].astype(jnp.float32)
    rows = []
    for k in range(nb):
        sl = pl.dslice(base + k, block_p)
        xs = (xpad_ref[:, sl] * mpad_ref[:, sl]).astype(jnp.float32)
        rows.append(jnp.sum(xw * xs, axis=0))           # (block_p,)
    band_ref[...] = band_ref[...] \
        + jnp.stack(rows, axis=0).astype(band_ref.dtype)

    # --- stages, once per row block on the first feature step: identical
    # op order to _supervised_kernel/_monitor_kernel (the projection and
    # the VMEM-resident reconstruction are shared — the split kernels each
    # recomputed them from their own tile loads).  Rows come back out of
    # the halo slab at the EXACT width p, so a feature-padded chunk does
    # not widen the stage dots.
    @pl.when(i == 0)
    def _stages():
        x = xpad_ref[:, pl.dslice(h, p)].astype(jnp.float32)
        m = mpad_ref[:, pl.dslice(h, p)].astype(jnp.float32)
        w = basis_ref[...].astype(jnp.float32)          # (p, q)
        mean = mean_ref[...].astype(jnp.float32)        # (1, p)
        xc = (x - mean) * m
        z = jnp.dot(xc, w, preferred_element_type=jnp.float32)
        xh_r = jnp.dot(z, w.T, preferred_element_type=jnp.float32)
        z_ref[...] = z.astype(z_ref.dtype)
        if with_compress:
            xh = xh_r + mean
            err = jnp.abs(x - xh)
            flags = jnp.where((err > eps) & (m > 0.0), 1.0, 0.0)
            xh_ref[...] = xh.astype(xh_ref.dtype)
            flag_ref[...] = flags.astype(flag_ref.dtype)
        if with_monitor:
            il = invlam_ref[...].astype(jnp.float32)    # (1, q)
            resid = (xc - xh_r) * m
            t2_ref[...] = jnp.sum(z * z * il, axis=1,
                                  keepdims=True).astype(t2_ref.dtype)
            spe_ref[...] = jnp.sum(resid * resid, axis=1,
                                   keepdims=True).astype(spe_ref.dtype)


def fused_stream_pallas(x: jnp.ndarray, x_padded: jnp.ndarray,
                        mask: jnp.ndarray, mask_padded: jnp.ndarray,
                        w_rows: jnp.ndarray, basis: jnp.ndarray,
                        mean: jnp.ndarray, inv_lam: jnp.ndarray,
                        *, halfwidth: int, epsilon: float,
                        with_compress: bool, with_monitor: bool,
                        block_p: int, block_n: int, interpret: bool = False,
                        ) -> tuple[jnp.ndarray, ...]:
    """One fused chunk pass: band fold + compression + monitoring.

    ``x`` is the flattened chunk (rows, p_pad) (rows = K·n padded to
    ``block_n``, features padded to ``block_p``); ``x_padded`` its
    (rows, p_pad + 2h) halo form; ``mask`` / ``mask_padded`` the per-row
    0/1 validity (liveness × round validity — pad rows carry mask 0 AND
    weight 0, pad features mask 0); ``w_rows`` (rows, 1) the per-row
    forgetting weights; ``basis`` (p, q), ``mean`` (1, p) and ``inv_lam``
    (1, q) the stage operands at the EXACT sensor count p (p <= p_pad),
    replicated to every grid step.

    Returns ``(band, z[, x_hat, flags][, t2, spe])`` — band (2h+1, p_pad)
    and per-row stage outputs at exact width, all fp32, gated by the
    static ``with_*`` flags (at least one must be set; a band-only chunk
    has no reason to pay the stage operand traffic — use the cov-update
    kernel).
    """
    rows, p_pad = x.shape
    h = halfwidth
    nb = 2 * h + 1
    p, q = basis.shape
    assert p <= p_pad, (p, p_pad)
    assert with_compress or with_monitor, "band-only: use cov_band_update"
    assert rows % block_n == 0, (rows, block_n)
    assert p_pad % block_p == 0, (p_pad, block_p)
    assert x_padded.shape == (rows, p_pad + 2 * h)
    assert mask.shape == (rows, p_pad)
    assert mask_padded.shape == (rows, p_pad + 2 * h)
    assert w_rows.shape == (rows, 1)
    assert mean.shape == (1, p) and inv_lam.shape == (1, q)
    grid = (p_pad // block_p, rows // block_n)
    in_specs = [
        pl.BlockSpec((block_n, block_p), lambda i, j: (j, i)),      # x
        pl.BlockSpec((block_n, p_pad + 2 * h), lambda i, j: (j, 0)),
        pl.BlockSpec((block_n, block_p), lambda i, j: (j, i)),      # mask
        pl.BlockSpec((block_n, p_pad + 2 * h), lambda i, j: (j, 0)),
        pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),            # weights
        pl.BlockSpec((p, q), lambda i, j: (0, 0)),                  # basis
        pl.BlockSpec((1, p), lambda i, j: (0, 0)),                  # mean
        pl.BlockSpec((1, q), lambda i, j: (0, 0)),                  # inv_lam
    ]
    # the band accumulator block is revisited consecutively by the row
    # sweep of its feature block (j-constant index map); the stage outputs
    # advance with the row blocks and are written on the first feature step
    out_specs = [
        pl.BlockSpec((nb, block_p), lambda i, j: (0, i)),           # band
        pl.BlockSpec((block_n, q), lambda i, j: (j, 0)),            # z
    ]
    out_shape = [
        jax.ShapeDtypeStruct((nb, p_pad), jnp.float32),
        jax.ShapeDtypeStruct((rows, q), jnp.float32),
    ]
    if with_compress:
        out_specs += [pl.BlockSpec((block_n, p), lambda i, j: (j, 0)),
                      pl.BlockSpec((block_n, p), lambda i, j: (j, 0))]
        out_shape += [jax.ShapeDtypeStruct((rows, p), jnp.float32),
                      jax.ShapeDtypeStruct((rows, p), jnp.float32)]
    if with_monitor:
        out_specs += [pl.BlockSpec((block_n, 1), lambda i, j: (j, 0)),
                      pl.BlockSpec((block_n, 1), lambda i, j: (j, 0))]
        out_shape += [jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                      jax.ShapeDtypeStruct((rows, 1), jnp.float32)]
    return pl.pallas_call(
        functools.partial(_fused_kernel, nb=nb, p=p, eps=float(epsilon),
                          with_compress=with_compress,
                          with_monitor=with_monitor),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, x_padded, mask, mask_padded, w_rows, basis, mean, inv_lam)
