"""Pallas TPU kernel: PCAg projection / reconstruction (Eq. 5-6).

``Z = X W`` (scores) and ``X_hat = Z W^T`` (reconstruction) for measurement
batches X (n, p) and a tall-skinny basis W (p, q).  These are the per-epoch
PCAg compute at the sink/nodes and the inner products of the orthogonal-
iteration Gram step, so they are on the paper's critical path.

Tiling: classic k-accumulation matmul. The contraction (feature) axis p is
the inner grid dimension; each step issues a (block_n x block_k) @
(block_k x q) MXU matmul accumulated into a VMEM-resident (block_n, q)
output tile in fp32.  q is small (# components) so the full q stays in the
minor dimension — pick block shapes that are multiples of (8, 128) on real
hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pca_project_pallas", "pca_reconstruct_pallas"]


def _project_kernel(x_ref, w_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] + jnp.dot(
        x_ref[...], w_ref[...],
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def pca_project_pallas(x: jnp.ndarray, w: jnp.ndarray,
                       *, block_n: int, block_k: int,
                       interpret: bool = False) -> jnp.ndarray:
    """Z (n, q) = X (n, p) @ W (p, q), k-accumulated over p."""
    n, p = x.shape
    p2, q = w.shape
    assert p == p2
    assert n % block_n == 0 and p % block_k == 0, (n, p, block_n, block_k)
    grid = (n // block_n, p // block_k)                  # contraction inner
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, k: (i, k)),
            pl.BlockSpec((block_k, q), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, q), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, q), jnp.float32),
        interpret=interpret,
    )(x, w)


def _reconstruct_kernel(z_ref, w_ref, out_ref):
    out_ref[...] = jnp.dot(
        z_ref[...], w_ref[...].T,
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def pca_reconstruct_pallas(z: jnp.ndarray, w: jnp.ndarray,
                           *, block_n: int, block_p: int,
                           interpret: bool = False) -> jnp.ndarray:
    """X_hat (n, p) = Z (n, q) @ W^T; single pass (q not blocked)."""
    n, q = z.shape
    p, q2 = w.shape
    assert q == q2
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    grid = (n // block_n, p // block_p)
    return pl.pallas_call(
        _reconstruct_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, q), lambda i, j: (i, 0)),
            pl.BlockSpec((block_p, q), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=interpret,
    )(z, w)
