"""Pallas TPU kernels: PCAg projection / reconstruction (Eq. 5-6) and the
fused epsilon-supervised compression pass (Sec. 2.4.1).

``Z = X W`` (scores) and ``X_hat = Z W^T`` (reconstruction) for measurement
batches X (n, p) and a tall-skinny basis W (p, q).  These are the per-epoch
PCAg compute at the sink/nodes and the inner products of the orthogonal-
iteration Gram step, so they are on the paper's critical path.

Tiling: classic k-accumulation matmul. The contraction (feature) axis p is
the inner grid dimension; each step issues a (block_n x block_k) @
(block_k x q) MXU matmul accumulated into a VMEM-resident (block_n, q)
output tile in fp32.  q is small (# components) so the full q stays in the
minor dimension — pick block shapes that are multiples of (8, 128) on real
hardware.

:func:`supervised_compress_pallas` fuses the whole supervised-compression
epoch — center, project, reconstruct, error test — into ONE pass over X:
each grid step loads a (block_n, p) measurement slab plus the full basis,
computes Z = (X - mean) W and X_hat = Z W^T + mean back-to-back on the MXU
(Z never round-trips to HBM), and emits the scores, the reconstruction and
the per-node notification mask ``|x - x_hat| > eps``.  The feature axis is
deliberately unblocked: a WSN basis is tall-skinny (p up to a few thousand,
q tens), so a (block_n, p) slab + (p, q) basis fit VMEM comfortably and the
fusion saves two of the three HBM round-trips of the composed
project -> reconstruct -> compare pipeline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pca_project_pallas", "pca_reconstruct_pallas",
           "supervised_compress_pallas", "pca_monitor_pallas"]


def _project_kernel(x_ref, w_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = out_ref[...] + jnp.dot(
        x_ref[...], w_ref[...],
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def pca_project_pallas(x: jnp.ndarray, w: jnp.ndarray,
                       *, block_n: int, block_k: int,
                       interpret: bool = False) -> jnp.ndarray:
    """Z (n, q) = X (n, p) @ W (p, q), k-accumulated over p."""
    n, p = x.shape
    p2, q = w.shape
    assert p == p2
    assert n % block_n == 0 and p % block_k == 0, (n, p, block_n, block_k)
    grid = (n // block_n, p // block_k)                  # contraction inner
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_k), lambda i, k: (i, k)),
            pl.BlockSpec((block_k, q), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, q), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, q), jnp.float32),
        interpret=interpret,
    )(x, w)


def _reconstruct_kernel(z_ref, w_ref, out_ref):
    out_ref[...] = jnp.dot(
        z_ref[...], w_ref[...].T,
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def pca_reconstruct_pallas(z: jnp.ndarray, w: jnp.ndarray,
                           *, block_n: int, block_p: int,
                           interpret: bool = False) -> jnp.ndarray:
    """X_hat (n, p) = Z (n, q) @ W^T; single pass (q not blocked)."""
    n, q = z.shape
    p, q2 = w.shape
    assert q == q2
    assert n % block_n == 0 and p % block_p == 0, (n, p, block_n, block_p)
    grid = (n // block_n, p // block_p)
    return pl.pallas_call(
        _reconstruct_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, q), lambda i, j: (i, 0)),
            pl.BlockSpec((block_p, q), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, p), jnp.float32),
        interpret=interpret,
    )(z, w)


def _supervised_kernel(x_ref, w_ref, mean_ref, mask_ref,
                       z_ref, xh_ref, flag_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (block_n, p)
    w = w_ref[...].astype(jnp.float32)                  # (p, q)
    mean = mean_ref[...].astype(jnp.float32)            # (1, p)
    m = mask_ref[...].astype(jnp.float32)               # (block_n, p)
    # dead sensors transmit no init record: they are absent from the A sum
    xc = (x - mean) * m
    z = jnp.dot(xc, w, preferred_element_type=jnp.float32)
    xh = jnp.dot(z, w.T, preferred_element_type=jnp.float32) + mean
    err = jnp.abs(x - xh)
    # Sec. 2.4.1 convention: notify on err > eps, so every un-flagged entry
    # satisfies |x - x_hat| <= eps (the closed-bound sink guarantee)
    flags = jnp.where((err > eps) & (m > 0.0), 1.0, 0.0)
    z_ref[...] = z.astype(z_ref.dtype)
    xh_ref[...] = xh.astype(xh_ref.dtype)
    flag_ref[...] = flags.astype(flag_ref.dtype)


def _monitor_kernel(x_ref, w_ref, mean_ref, invlam_ref, mask_ref,
                    z_ref, t2_ref, spe_ref):
    x = x_ref[...].astype(jnp.float32)                  # (block_n, p)
    w = w_ref[...].astype(jnp.float32)                  # (p, q)
    mean = mean_ref[...].astype(jnp.float32)            # (1, p)
    il = invlam_ref[...].astype(jnp.float32)            # (1, q)
    m = mask_ref[...].astype(jnp.float32)               # (block_n, p)
    # dead sensors transmit no init record: absent from the A sum
    xc = (x - mean) * m
    z = jnp.dot(xc, w, preferred_element_type=jnp.float32)
    # the reconstruction never leaves VMEM: only its residual energy does.
    # Sec. 2.4.3 monitoring pair — top-space T^2 = sum_k z_k^2 / lambda_k
    # catches energy moving WITHIN the tracked subspace; SPE (the Q
    # statistic) ||(x - mean) - z W^T||^2 over live sensors catches
    # network-coherent events the basis does not span (the streaming
    # analogue of the paper's low-variance evaluator).
    xh = jnp.dot(z, w.T, preferred_element_type=jnp.float32)
    resid = (xc - xh) * m
    t2 = jnp.sum(z * z * il, axis=1, keepdims=True)
    spe = jnp.sum(resid * resid, axis=1, keepdims=True)
    z_ref[...] = z.astype(z_ref.dtype)
    t2_ref[...] = t2.astype(t2_ref.dtype)
    spe_ref[...] = spe.astype(spe_ref.dtype)


def pca_monitor_pallas(x: jnp.ndarray, w: jnp.ndarray, mean: jnp.ndarray,
                       inv_lam: jnp.ndarray, mask: jnp.ndarray,
                       *, block_n: int, interpret: bool = False,
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One fused monitoring epoch (Sec. 2.4.3 on the streaming path).

    The ε-supervised pass extended to event detection: center, project,
    reconstruct and reduce in ONE pass over X.  Emits the scores Z (n, q)
    plus two per-epoch statistics — T² = Σ_k z_k²/λ̂_k (n, 1) and
    SPE = ‖(x − mean)·mask − Z Wᵀ‖² (n, 1).  The (block_n, p)
    reconstruction stays VMEM-resident (it is consumed by a single VPU
    reduction), so the monitoring tier adds ZERO (n, p)-sized HBM
    round-trips on top of the projection.  ``inv_lam`` (1, q) carries the
    reciprocal per-component variance estimates (clamping is the caller's
    job — the kernel multiplies).  Thresholding happens outside the kernel:
    the alarm thresholds are *traced* state (recalibrated after every
    refresh), not compile-time constants.
    """
    n, p = x.shape
    p2, q = w.shape
    assert p == p2
    assert mean.shape == (1, p) and inv_lam.shape == (1, q)
    assert mask.shape == (n, p)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        _monitor_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((p, q), lambda i: (0, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((1, q), lambda i: (0, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, q), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, q), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, mean, inv_lam, mask)


def supervised_compress_pallas(x: jnp.ndarray, w: jnp.ndarray,
                               mean: jnp.ndarray, mask: jnp.ndarray,
                               *, epsilon: float, block_n: int,
                               interpret: bool = False,
                               ) -> tuple[jnp.ndarray, jnp.ndarray,
                                          jnp.ndarray]:
    """One fused supervised-compression epoch (Sec. 2.4.1).

    Z (n, q), X_hat (n, p), flags (n, p) from X (n, p), W (p, q),
    mean (1, p) and a 0/1 liveness/validity mask (n, p), in a single pass:
    ``Z = ((X - mean) * mask) W``; ``X_hat = Z W^T + mean``;
    ``flags = (|X - X_hat| > eps) & mask``.  ``eps`` is a compile-time
    constant (the serving tier fixes it per deployment; sweeps recompile).
    The grid blocks the batch axis only — see the module docstring.
    """
    n, p = x.shape
    p2, q = w.shape
    assert p == p2
    assert mean.shape == (1, p) and mask.shape == (n, p)
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_supervised_kernel, eps=float(epsilon)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((p, q), lambda i: (0, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, q), lambda i: (i, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, q), jnp.float32),
            jax.ShapeDtypeStruct((n, p), jnp.float32),
            jax.ShapeDtypeStruct((n, p), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, mean, mask)
