"""Pure-jnp oracles for the Pallas kernels.

Each function is the mathematical definition of the corresponding kernel,
written with plain jnp ops only (no pallas, no custom control flow), used by
tests/test_kernels.py as the allclose reference across shape/dtype sweeps.

Layouts (shared with repro.core.covariance):
* banded matrix: ``band[k, i] = C[i, i + k - h]`` for ``k in [0, 2h]``,
  out-of-range entries are zero.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["banded_matvec", "banded_matmul", "cov_band_update",
           "cov_band_update_masked", "cov_band_update_chunk",
           "cov_band_update_chunk_masked", "pca_project", "pca_reconstruct",
           "supervised_compress", "pca_monitor",
           "fused_stream"]


def _shifted_cols(x: jnp.ndarray, offset: int) -> jnp.ndarray:
    """out[..., j] = x[..., j + offset], zero outside the valid range."""
    p = x.shape[-1]
    rolled = jnp.roll(x, -offset, axis=-1)
    j = jnp.arange(p)
    valid = (j + offset >= 0) & (j + offset < p)
    return jnp.where(valid, rolled, jnp.zeros_like(rolled))


def banded_matvec(band: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """y[i] = sum_k band[k, i] * v[i + k - h]   (the paper's local Cv)."""
    nb, p = band.shape
    h = (nb - 1) // 2
    acc = jnp.zeros_like(v)
    for k in range(nb):
        acc = acc + band[k] * _shifted_cols(v[None, :], k - h)[0]
    return acc


def banded_matmul(band: jnp.ndarray, V: jnp.ndarray) -> jnp.ndarray:
    """Y[i, c] = sum_k band[k, i] * V[i + k - h, c]  (blocked PIM variant)."""
    nb, p = band.shape
    h = (nb - 1) // 2
    acc = jnp.zeros_like(V)
    for k in range(nb):
        acc = acc + band[k][:, None] * _shifted_cols(V.T, k - h).T
    return acc


def cov_band_update(x: jnp.ndarray, halfwidth: int) -> jnp.ndarray:
    """delta[k, i] = sum_t x[t, i] * x[t, i + k - h]  (Eq. 10, banded)."""
    h = halfwidth
    rows = []
    for k in range(2 * h + 1):
        rows.append(jnp.sum(x * _shifted_cols(x, k - h), axis=0))
    return jnp.stack(rows, axis=0)


def cov_band_update_masked(x: jnp.ndarray, mask: jnp.ndarray,
                           halfwidth: int) -> jnp.ndarray:
    """Masked Eq. 10: entries with mask 0 contribute to no band product."""
    mask = jnp.asarray(mask, dtype=x.dtype)
    if mask.ndim == 1:
        mask = jnp.broadcast_to(mask[None, :], x.shape)
    return cov_band_update(x * mask, halfwidth)


def cov_band_update_chunk(xs: jnp.ndarray, weights: jnp.ndarray,
                          halfwidth: int) -> jnp.ndarray:
    """Multi-round weighted Eq. 10: the per-round bands scaled by each
    round's chunk weight (gamma^(K-1-t) in the streaming fold; 0 for a
    padded round) and summed — ``delta = sum_t w[t] * band(xs[t])``."""
    weights = jnp.asarray(weights, jnp.float32)
    bands = jnp.stack([cov_band_update(xs[t], halfwidth)
                       for t in range(xs.shape[0])], axis=0)
    return jnp.einsum("t,tkp->kp", weights, bands)


def cov_band_update_chunk_masked(xs: jnp.ndarray, masks: jnp.ndarray,
                                 weights: jnp.ndarray,
                                 halfwidth: int) -> jnp.ndarray:
    """Masked chunk variant: ``delta = sum_t w[t] * band(xs[t] * m[t])``.

    ``masks`` is (K, p) per-round liveness or (K, n, p) per-reading
    dropout, broadcast like :func:`cov_band_update_masked`."""
    masks = jnp.asarray(masks, xs.dtype)
    if masks.ndim == 2:
        masks = jnp.broadcast_to(masks[:, None, :], xs.shape)
    weights = jnp.asarray(weights, jnp.float32)
    bands = jnp.stack([cov_band_update(xs[t] * masks[t], halfwidth)
                       for t in range(xs.shape[0])], axis=0)
    return jnp.einsum("t,tkp->kp", weights, bands)


def pca_project(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Z = X W — the PCAg scores (Eq. 6) for a batch of measurement rows."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def pca_reconstruct(z: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """X_hat = Z W^T — the approximation of Eq. (5)."""
    return jnp.dot(z, w.T, preferred_element_type=jnp.float32).astype(z.dtype)


def supervised_compress(x: jnp.ndarray, w: jnp.ndarray, mean: jnp.ndarray,
                        mask: jnp.ndarray, epsilon: float,
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The fused supervised-compression epoch (Sec. 2.4.1), unfused.

    Same fp32 arithmetic as the Pallas kernel, written as three plain dots:
    ``Z = ((X - mean) * mask) W``; ``X_hat = Z W^T + mean``;
    ``flags = (|X - X_hat| > eps) & mask`` — notify on strictly-greater,
    guarantee the closed bound ``<= eps`` for everything un-flagged.
    """
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    mean = jnp.asarray(mean, jnp.float32).reshape(1, -1)
    mask = jnp.asarray(mask, jnp.float32)
    if mask.ndim == 1:
        mask = jnp.broadcast_to(mask[None, :], x.shape)
    xc = (x - mean) * mask
    z = jnp.dot(xc, w, preferred_element_type=jnp.float32)
    xh = jnp.dot(z, w.T, preferred_element_type=jnp.float32) + mean
    flags = (jnp.abs(x - xh) > epsilon) & (mask > 0.0)
    return z, xh, flags


def pca_monitor(x: jnp.ndarray, w: jnp.ndarray, mean: jnp.ndarray,
                inv_lam: jnp.ndarray, mask: jnp.ndarray,
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The fused monitoring epoch (Sec. 2.4.3), unfused.

    Same fp32 arithmetic as the Pallas kernel, written as two plain dots
    plus two row reductions: ``Z = ((X - mean) * mask) W``;
    ``T²[t] = Σ_k Z[t, k]² inv_lam[k]``;
    ``SPE[t] = ‖(X[t] - mean)·mask − Z[t] Wᵀ‖²`` over live sensors.
    """
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    mean = jnp.asarray(mean, jnp.float32).reshape(1, -1)
    inv_lam = jnp.asarray(inv_lam, jnp.float32).reshape(1, -1)
    mask = jnp.asarray(mask, jnp.float32)
    if mask.ndim == 1:
        mask = jnp.broadcast_to(mask[None, :], x.shape)
    xc = (x - mean) * mask
    z = jnp.dot(xc, w, preferred_element_type=jnp.float32)
    xh = jnp.dot(z, w.T, preferred_element_type=jnp.float32)
    resid = (xc - xh) * mask
    t2 = jnp.sum(z * z * inv_lam, axis=1)
    spe = jnp.sum(resid * resid, axis=1)
    return z, t2, spe


def fused_stream(xs: jnp.ndarray, weights: jnp.ndarray, w: jnp.ndarray,
                 mean: jnp.ndarray, inv_lam: jnp.ndarray, halfwidth: int,
                 epsilon: float, mask: jnp.ndarray | None = None,
                 ) -> tuple[jnp.ndarray, ...]:
    """The one-pass fused chunk epoch (DESIGN.md Sec. 14), unfused.

    ``xs`` is the flattened chunk (rows, p), ``weights`` (rows,) the
    per-row forgetting weights, ``mask`` per-row 0/1 validity (None = all
    live).  Composes the existing oracles: the forgetting-weighted band
    fold of :func:`cov_band_update_chunk_masked` (rows treated as a
    K=rows, n=1 chunk), :func:`supervised_compress` and
    :func:`pca_monitor` — returns ``(band, z, x_hat, flags, t2, spe)``.
    """
    rows, p = xs.shape
    if mask is None:
        mask = jnp.ones((rows, p), jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    if mask.ndim == 1:
        mask = jnp.broadcast_to(mask[None, :], (rows, p))
    band = cov_band_update_chunk_masked(xs[:, None, :], mask[:, None, :],
                                        jnp.asarray(weights, jnp.float32),
                                        halfwidth)
    z, xh, flags = supervised_compress(xs, w, mean, mask, epsilon)
    _, t2, spe = pca_monitor(xs, w, mean, inv_lam, mask)
    return band, z, xh, flags, t2, spe
