"""Jitted public wrappers around the Pallas kernels.

Handles padding, block-size selection, dtype promotion and the
interpret-mode fallback (this container is CPU-only: ``interpret=True``
executes the kernel bodies in Python for correctness validation; on real TPU
the same code path compiles to Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.banded_matvec import banded_matvec_pallas, banded_matmul_pallas
from repro.kernels.cov_update import (cov_band_update_chunk_masked_pallas,
                                      cov_band_update_chunk_pallas,
                                      cov_band_update_pallas,
                                      cov_band_update_masked_pallas)
from repro.kernels.fused_stream import fused_stream_pallas
from repro.kernels.pca_project import (pca_monitor_pallas,
                                       pca_project_pallas,
                                       pca_reconstruct_pallas,
                                       supervised_compress_pallas)

__all__ = ["banded_matvec", "banded_matmul", "cov_band_update",
           "cov_band_update_masked", "cov_band_update_batched",
           "cov_band_update_chunk", "cov_band_update_chunk_batched",
           "pca_project", "pca_reconstruct",
           "supervised_compress", "supervised_compress_batched",
           "pca_monitor", "pca_monitor_batched",
           "fused_stream_update", "fused_stream_stages_blocked",
           "kernel_block_plan"]


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.lru_cache(maxsize=None)
def _targets(kind: str, dtype: str = "fp32") -> tuple[int, int]:
    """(row target, feature target) for a kernel family — resolved per
    backend through :func:`repro.launch.tiling.block_targets` instead of
    the old hard-coded (128, 512).  Non-TPU backends (this CI container)
    get the historical numbers back, so interpret-mode bits are unchanged.
    """
    from repro.launch.tiling import block_targets
    t = block_targets(kind, dtype=dtype)
    return t["rows"], t["features"]


def _pick_block(p: int, target: int = 512) -> int:
    """Largest divisor of p that is <= target (prefers multiples of 128)."""
    for cand in (target, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= target and p % cand == 0:
            return cand
    return 1


def _pick_block_padded(d: int, target: int) -> int:
    """Block size for an axis the caller is allowed to zero-pad.

    Prefers the exact-divisor pick — no padding, so results stay
    bit-identical to the historical behavior on every shape a divisor
    covers — and only when the best divisor is degenerate (awkward ``d``,
    e.g. prime: the old path would tile by 1, a pathological grid)
    switches to a padded power-of-two tile.  The wrappers below pad the
    operand up to a multiple of the returned block and slice the result
    back.
    """
    b = _pick_block(d, target)
    if b > 1 or d <= 8:
        return b
    return min(target, 1 << (d - 1).bit_length())


def _pad_dim(d: int, block: int) -> int:
    return -(-d // block) * block


def kernel_block_plan(kind: str, *, rows: int | None = None,
                      p: int | None = None, dtype: str = "fp32",
                      halfwidth: int | None = None) -> dict:
    """The BlockSpec plan a wrapper will pick for the given logical shapes.

    The single source of tiling truth shared by the wrappers below (which
    call it to pick their blocks) and by the static resource certifier
    (:mod:`repro.analysis.resources`), which uses it as the *booked* side
    of the booked==traced VMEM/HBM bill — the plan and the traced
    ``pallas_call`` grid cannot drift apart without a rule failing.

    Returns ``block_n``/``rows_pad``/``row_blocks`` when ``rows`` is
    given, ``block_p``/``p_pad``/``feature_blocks`` when ``p`` is given,
    plus ``grid`` (feature-major, rows fastest — the kernel convention)
    when both are, and ``halo_width`` (the full-width padded slab a banded
    kernel re-fetches per feature block) when ``halfwidth`` is given too.
    """
    rt, ft = _targets(kind, dtype)
    plan: dict = {"row_target": rt, "feature_target": ft}
    if p is not None:
        bp = _pick_block_padded(p, ft)
        plan.update(block_p=bp, p_pad=_pad_dim(p, bp),
                    feature_blocks=_pad_dim(p, bp) // bp)
    if rows is not None:
        bn = _pick_block_padded(rows, rt)
        plan.update(block_n=bn, rows_pad=_pad_dim(rows, bn),
                    row_blocks=_pad_dim(rows, bn) // bn)
    if rows is not None and p is not None:
        plan["grid"] = (plan["feature_blocks"], plan["row_blocks"])
    if halfwidth is not None and p is not None:
        plan["halo_width"] = plan["p_pad"] + 2 * halfwidth
    return plan


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def _banded_matvec(band, v, block_p, interpret):
    nb = band.shape[0]
    h = (nb - 1) // 2
    vpad = jnp.pad(v, (h, h)).reshape(1, -1)
    out = banded_matvec_pallas(band, vpad, block_p=block_p, interpret=interpret)
    return out[0]


def banded_matvec(band: jnp.ndarray, v: jnp.ndarray,
                  block_p: int | None = None,
                  interpret: bool | None = None,
                  out_dtype=None) -> jnp.ndarray:
    """y = C v with C banded (2h+1, p) diagonals; v (p,).

    Accumulates in fp32 inside the kernel whatever the operand dtype; the
    output is ``out_dtype`` (default: the band's dtype — a bf16 band stays
    bf16 instead of silently upcasting).  An awkward ``p`` (e.g. prime —
    the old divisor fallback tiled it by 1, a pathological grid) is
    zero-padded to the block and sliced back: pad columns hold zero band
    entries, so the surviving region is bit-identical.
    """
    nb, p = band.shape
    bp = block_p or _pick_block_padded(p, _targets("banded")[1])
    p_pad = _pad_dim(p, bp)
    if p_pad != p:
        band = jnp.pad(band, ((0, 0), (0, p_pad - p)))
        v = jnp.pad(v, (0, p_pad - p))
    out = _banded_matvec(band, v, bp, _auto_interpret(interpret))[:p]
    return out if out_dtype is None else out.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def _banded_matmul(band, V, block_p, interpret):
    nb = band.shape[0]
    h = (nb - 1) // 2
    vpad = jnp.pad(V, ((h, h), (0, 0)))
    return banded_matmul_pallas(band, vpad, block_p=block_p, interpret=interpret)


def banded_matmul(band: jnp.ndarray, V: jnp.ndarray,
                  block_p: int | None = None,
                  interpret: bool | None = None,
                  out_dtype=None) -> jnp.ndarray:
    """Y = C V with C banded; V (p, q).

    Same pad-to-block treatment and dtype policy as
    :func:`banded_matvec` (fp32 accumulate; output follows the band).
    """
    nb, p = band.shape
    bp = block_p or _pick_block_padded(p, _targets("banded")[1])
    p_pad = _pad_dim(p, bp)
    if p_pad != p:
        band = jnp.pad(band, ((0, 0), (0, p_pad - p)))
        V = jnp.pad(V, ((0, p_pad - p), (0, 0)))
    out = _banded_matmul(band, V, bp, _auto_interpret(interpret))[:p]
    return out if out_dtype is None else out.astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("halfwidth", "block_p", "block_n",
                                    "interpret"))
def _cov_band_update(x, halfwidth, block_p, block_n, interpret):
    h = halfwidth
    xpad = jnp.pad(x, ((0, 0), (h, h)))
    return cov_band_update_pallas(x, xpad, halfwidth=h, block_p=block_p,
                                  block_n=block_n, interpret=interpret)


def cov_band_update(x: jnp.ndarray, halfwidth: int,
                    block_p: int | None = None, block_n: int | None = None,
                    interpret: bool | None = None,
                    out_dtype=None) -> jnp.ndarray:
    """delta band (2h+1, p) = sum_t outer(x_t, x_t) restricted to the band.

    Accumulates in fp32 inside the kernel whatever ``x``'s dtype; the
    output is ``out_dtype`` (default fp32 — the historical contract; pass
    the state dtype to keep a bf16-configured engine's sufficient
    statistics in bf16 without a silent upcast).  Awkward shapes (e.g.
    prime ``p`` — the old divisor fallback degraded to ``block_p=1``, a
    silent up-to-512× tiling pessimization on the per-round path) are
    zero-padded to the block grid and sliced back: pad rows/columns are
    exact zero contributions, and every divisor-covered shape keeps its
    historical tiling bit-identically.
    """
    n, p = x.shape
    rt, ft = _targets("cov")
    bp = block_p or _pick_block_padded(p, ft)
    bn = block_n or _pick_block_padded(n, rt)
    n_pad, p_pad = _pad_dim(n, bn), _pad_dim(p, bp)
    if (n_pad, p_pad) != (n, p):
        x = jnp.pad(x, ((0, n_pad - n), (0, p_pad - p)))
    out = _cov_band_update(x, halfwidth, bp, bn,
                           _auto_interpret(interpret))[:, :p]
    return out if out_dtype is None else out.astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("halfwidth", "block_p", "block_n",
                                    "interpret"))
def _cov_band_update_masked(x, mask, halfwidth, block_p, block_n, interpret):
    h = halfwidth
    xpad = jnp.pad(x, ((0, 0), (h, h)))
    mpad = jnp.pad(mask, ((0, 0), (h, h)))
    return cov_band_update_masked_pallas(x, xpad, mask, mpad, halfwidth=h,
                                         block_p=block_p, block_n=block_n,
                                         interpret=interpret)


def cov_band_update_masked(x: jnp.ndarray, mask: jnp.ndarray, halfwidth: int,
                           block_p: int | None = None,
                           block_n: int | None = None,
                           interpret: bool | None = None) -> jnp.ndarray:
    """Masked delta band: products where either entry is masked contribute 0.

    ``mask`` is a 0/1 validity array, either (p,) — a sensor-liveness mask
    broadcast over the batch (dead motes) — or (n, p) for per-reading
    measurement dropout.  The multiply is fused into the kernel's tile
    loads: no masked copy of ``x`` is materialized in HBM, though the mask
    itself streams alongside ``x`` (a (p,) mask is broadcast to the batch
    shape first, so the masked update reads roughly twice the input bytes
    of the unmasked kernel — acceptable for a VPU-bound kernel, and the
    ``mask=None`` fast path in callers keeps the fault-free fleet at
    unmasked cost).
    """
    n, p = x.shape
    mask = jnp.asarray(mask, dtype=x.dtype)
    if mask.ndim == 1:
        mask = jnp.broadcast_to(mask[None, :], (n, p))
    if mask.shape != (n, p):
        raise ValueError(f"mask shape {mask.shape} incompatible with {(n, p)}")
    rt, ft = _targets("cov")
    bp = block_p or _pick_block_padded(p, ft)
    bn = block_n or _pick_block_padded(n, rt)
    n_pad, p_pad = _pad_dim(n, bn), _pad_dim(p, bp)
    if (n_pad, p_pad) != (n, p):
        x = jnp.pad(x, ((0, n_pad - n), (0, p_pad - p)))
        mask = jnp.pad(mask, ((0, n_pad - n), (0, p_pad - p)))
    out = _cov_band_update_masked(x, mask, halfwidth, bp, bn,
                                  _auto_interpret(interpret))
    return out[:, :p]


def cov_band_update_batched(x: jnp.ndarray, halfwidth: int,
                            block_p: int | None = None,
                            block_n: int | None = None,
                            interpret: bool | None = None) -> jnp.ndarray:
    """Per-network delta bands (B, 2h+1, p) from a fleet batch x (B, n, p).

    The networks axis is independent (no cross-network products), so the
    batched form is a ``vmap`` of the single-network kernel: Pallas turns the
    batch dimension into an extra outer grid axis, keeping the per-network
    tiling identical to :func:`cov_band_update`.  The streaming fleet driver
    reaches the same composition implicitly (``vmap`` over
    ``online_update``); this explicit wrapper is for callers that hold a
    (networks, n, p) block outside the driver — fleet-wide preprocessing,
    benchmarks, ad-hoc analysis.
    """
    if x.ndim != 3:
        raise ValueError(f"expected (networks, n, p), got {x.shape}")
    _, n, p = x.shape
    itp = _auto_interpret(interpret)
    return jax.vmap(
        lambda xi: cov_band_update(xi, halfwidth, block_p=block_p,
                                   block_n=block_n, interpret=itp))(x)


@functools.partial(jax.jit,
                   static_argnames=("halfwidth", "block_p", "block_n",
                                    "interpret"))
def _cov_band_update_chunk(x, w, halfwidth, block_p, block_n, interpret):
    h = halfwidth
    xpad = jnp.pad(x, ((0, 0), (h, h)))
    return cov_band_update_chunk_pallas(x, xpad, w, halfwidth=h,
                                        block_p=block_p, block_n=block_n,
                                        interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("halfwidth", "block_p", "block_n",
                                    "interpret"))
def _cov_band_update_chunk_masked(x, mask, w, halfwidth, block_p, block_n,
                                  interpret):
    h = halfwidth
    xpad = jnp.pad(x, ((0, 0), (h, h)))
    mpad = jnp.pad(mask, ((0, 0), (h, h)))
    return cov_band_update_chunk_masked_pallas(
        x, xpad, mask, mpad, w, halfwidth=h, block_p=block_p,
        block_n=block_n, interpret=interpret)


def cov_band_update_chunk(xs: jnp.ndarray, weights: jnp.ndarray,
                          halfwidth: int, *,
                          mask: jnp.ndarray | None = None,
                          block_p: int | None = None,
                          block_n: int | None = None,
                          interpret: bool | None = None) -> jnp.ndarray:
    """Fold a (K, n, p) chunk of rounds into one delta band in ONE launch.

    ``weights`` (K,) scales each round's contribution —
    ``delta[k, i] = sum_t w[t] sum_r xs[t, r, i] * xs[t, r, i + k - h]`` —
    the per-round exponential-forgetting factors of the streaming fold
    (``gamma^(K-1-t)``), with 0 marking a padded round.  ``mask`` is an
    optional validity array, (K, p) per-round liveness or (K, n, p)
    per-reading dropout, fused into the tile loads like
    :func:`cov_band_update_masked`.

    Pad-to-block treatment: the flattened (K·n) row axis is zero-padded to
    the block grid with ZERO-WEIGHT rows (an exact no-op product), and an
    awkward feature axis (e.g. prime p) is zero-padded and the band sliced
    back, exactly like :func:`pca_project`; divisor-covered shapes keep the
    historical tiling, so at K=1 / w=1 the result is bit-identical to
    :func:`cov_band_update`.
    """
    if xs.ndim != 3:
        raise ValueError(f"expected (chunk, n, p), got {xs.shape}")
    K, n, p = xs.shape
    weights = jnp.asarray(weights, jnp.float32)
    if weights.shape != (K,):
        raise ValueError(f"weights shape {weights.shape} != {(K,)}")
    rt, ft = _targets("cov")
    bp = block_p or _pick_block_padded(p, ft)
    # the row tile covers the FLATTENED chunk: a K-round chunk becomes
    # ~K-fold fewer grid cells than K per-round launches (at K=1 the pick
    # degenerates to the per-round choice — bit-identity preserved)
    bn = block_n or _pick_block_padded(K * n, rt)
    itp = _auto_interpret(interpret)
    x = xs.reshape(K * n, p)
    w = jnp.repeat(weights, n)[:, None]                 # (K*n, 1) row weights
    if mask is not None:
        mask = jnp.asarray(mask, xs.dtype)
        if mask.ndim == 2:
            if mask.shape != (K, p):
                raise ValueError(f"mask shape {mask.shape} != {(K, p)}")
            mask = jnp.broadcast_to(mask[:, None, :], (K, n, p))
        if mask.shape != (K, n, p):
            raise ValueError(f"mask shape {mask.shape} != {(K, n, p)}")
        mask = mask.reshape(K * n, p)
    rows_pad = _pad_dim(K * n, bn)
    p_pad = _pad_dim(p, bp)
    if (rows_pad, p_pad) != (K * n, p):
        x = jnp.pad(x, ((0, rows_pad - K * n), (0, p_pad - p)))
        w = jnp.pad(w, ((0, rows_pad - K * n), (0, 0)))
        if mask is not None:
            mask = jnp.pad(mask, ((0, rows_pad - K * n), (0, p_pad - p)))
    if mask is None:
        out = _cov_band_update_chunk(x, w, halfwidth, bp, bn, itp)
    else:
        out = _cov_band_update_chunk_masked(x, mask, w, halfwidth, bp, bn,
                                            itp)
    return out[:, :p]


def cov_band_update_chunk_batched(xs: jnp.ndarray, weights: jnp.ndarray,
                                  halfwidth: int, *,
                                  mask: jnp.ndarray | None = None,
                                  block_p: int | None = None,
                                  block_n: int | None = None,
                                  interpret: bool | None = None
                                  ) -> jnp.ndarray:
    """Fleet form of :func:`cov_band_update_chunk` over xs (B, K, n, p).

    ``weights`` is (B, K) per-network round weights (or (K,) shared),
    ``mask`` (B, K, p) / (B, K, n, p) / None.  A ``vmap`` of the fused
    chunk kernel: Pallas turns the networks axis into an extra outer grid
    axis, keeping the per-network tiling identical.
    """
    if xs.ndim != 4:
        raise ValueError(f"expected (networks, chunk, n, p), got {xs.shape}")
    B, K, n, p = xs.shape
    weights = jnp.asarray(weights, jnp.float32)
    if weights.ndim == 1:
        weights = jnp.broadcast_to(weights[None, :], (B, K))
    run = lambda xi, wi, mi: cov_band_update_chunk(
        xi, wi, halfwidth, mask=mi, block_p=block_p, block_n=block_n,
        interpret=interpret)
    if mask is None:
        return jax.vmap(lambda xi, wi: run(xi, wi, None))(xs, weights)
    return jax.vmap(run)(xs, weights, jnp.asarray(mask, xs.dtype))


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def _pca_project(x, w, block_n, block_k, interpret):
    return pca_project_pallas(x, w, block_n=block_n, block_k=block_k,
                              interpret=interpret)


def pca_project(x: jnp.ndarray, w: jnp.ndarray,
                block_n: int | None = None, block_k: int | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """Z = X W (PCAg scores for a batch of rows); any (n, p) works.

    Non-divisible shapes (awkward n, prime p, or an explicit block that
    does not divide the axis) are zero-padded up to the block grid and the
    result sliced back: padded feature columns multiply zero basis rows, so
    every fp32 partial sum they contribute is exactly 0.0 and the sliced
    result is bit-identical to the unpadded kernel at the same block sizes.
    """
    n, p = x.shape
    rt, ft = _targets("stage")
    bn = block_n or _pick_block_padded(n, rt)
    bk = block_k or _pick_block_padded(p, ft)
    n_pad, p_pad = _pad_dim(n, bn), _pad_dim(p, bk)
    if (n_pad, p_pad) != (n, p):
        x = jnp.pad(x, ((0, n_pad - n), (0, p_pad - p)))
        w = jnp.pad(w, ((0, p_pad - p), (0, 0)))
    out = _pca_project(x, w, bn, bk, _auto_interpret(interpret))
    return out[:n]


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_p", "interpret"))
def _pca_reconstruct(z, w, block_n, block_p, interpret):
    return pca_reconstruct_pallas(z, w, block_n=block_n, block_p=block_p,
                                  interpret=interpret)


def pca_reconstruct(z: jnp.ndarray, w: jnp.ndarray,
                    block_n: int | None = None, block_p: int | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """X_hat = Z W^T; any (n, p) works (padded + sliced like pca_project).

    Padded batch rows and padded basis rows produce extra output rows /
    columns that are sliced off; the surviving region is untouched (each
    output tile depends only on its own z rows and w rows).
    """
    n, q = z.shape
    p = w.shape[0]
    rt, ft = _targets("stage")
    bn = block_n or _pick_block_padded(n, rt)
    bp = block_p or _pick_block_padded(p, ft)
    n_pad, p_pad = _pad_dim(n, bn), _pad_dim(p, bp)
    if (n_pad, p_pad) != (n, p):
        z = jnp.pad(z, ((0, n_pad - n), (0, 0)))
        w = jnp.pad(w, ((0, p_pad - p), (0, 0)))
    out = _pca_reconstruct(z, w, bn, bp, _auto_interpret(interpret))
    return out[:n, :p]


@functools.partial(jax.jit,
                   static_argnames=("epsilon", "block_n", "interpret"))
def _supervised_compress(x, w, mean2d, mask, epsilon, block_n, interpret):
    return supervised_compress_pallas(x, w, mean2d, mask, epsilon=epsilon,
                                      block_n=block_n, interpret=interpret)


def supervised_compress(x: jnp.ndarray, w: jnp.ndarray,
                        mean: jnp.ndarray | None = None,
                        *, epsilon: float,
                        mask: jnp.ndarray | None = None,
                        block_n: int | None = None,
                        interpret: bool | None = None,
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One fused supervised-compression epoch (Sec. 2.4.1) on device.

    Returns ``(z, x_hat, flagged)``: scores (n, q) and reconstruction
    (n, p) in fp32, plus the bool notification mask ``|x - x_hat| > eps``
    (so every un-flagged entry is within the closed bound ``<= eps`` — the
    same convention as the NumPy oracle
    :class:`repro.core.compression.SupervisedCompressor`).  ``mask`` is an
    optional 0/1 liveness array, (p,) or (n, p); dead sensors contribute no
    score record and raise no notification.  ``epsilon`` is static (the
    kernel bakes it in); the batch axis is padded to the block like
    :func:`pca_project`, padded rows carry mask 0 so they project to
    nothing and never flag.
    """
    n, p = x.shape
    if mean is None:
        mean = jnp.zeros((p,), jnp.float32)
    mean2d = jnp.asarray(mean, jnp.float32).reshape(1, p)
    if mask is None:
        mask = jnp.ones((n, p), jnp.float32)
    else:
        mask = jnp.asarray(mask, jnp.float32)
        if mask.ndim == 1:
            mask = jnp.broadcast_to(mask[None, :], (n, p))
    bn = block_n or _pick_block_padded(n, _targets("stage")[0])
    n_pad = _pad_dim(n, bn)
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
        mask = jnp.pad(mask, ((0, n_pad - n), (0, 0)))
    z, x_hat, flags = _supervised_compress(x, w, mean2d, mask,
                                           float(epsilon), bn,
                                           _auto_interpret(interpret))
    return z[:n], x_hat[:n], flags[:n] > 0.0


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _pca_monitor(x, w, mean2d, invlam2d, mask, block_n, interpret):
    return pca_monitor_pallas(x, w, mean2d, invlam2d, mask,
                              block_n=block_n, interpret=interpret)


def pca_monitor(x: jnp.ndarray, w: jnp.ndarray,
                mean: jnp.ndarray | None = None,
                inv_lam: jnp.ndarray | None = None,
                *, mask: jnp.ndarray | None = None,
                block_n: int | None = None,
                interpret: bool | None = None,
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One fused monitoring epoch (Sec. 2.4.3) on device.

    Returns ``(z, t2, spe)``: scores (n, q) in fp32 plus the per-epoch
    statistics T² (n,) = Σ_k z_k²·inv_lam_k and SPE (n,) =
    ‖(x − mean)·mask − z Wᵀ‖² over live sensors — the same quantities the
    NumPy oracle (:class:`repro.core.events.LowVarianceDetector` /
    :func:`repro.kernels.ref.pca_monitor`) computes host-side.  ``inv_lam``
    defaults to all-ones (unnormalized T²); clamp the eigenvalue estimates
    *before* inverting.  ``mask`` is an optional 0/1 liveness array, (p,)
    or (n, p); dead sensors contribute no score record and no residual
    energy.  The batch axis is padded to the block like
    :func:`supervised_compress`; padded rows carry mask 0, so their scores
    and statistics are exactly zero and are sliced off.
    """
    n, p = x.shape
    q = w.shape[1]
    if mean is None:
        mean = jnp.zeros((p,), jnp.float32)
    mean2d = jnp.asarray(mean, jnp.float32).reshape(1, p)
    if inv_lam is None:
        inv_lam = jnp.ones((q,), jnp.float32)
    invlam2d = jnp.asarray(inv_lam, jnp.float32).reshape(1, q)
    if mask is None:
        mask = jnp.ones((n, p), jnp.float32)
    else:
        mask = jnp.asarray(mask, jnp.float32)
        if mask.ndim == 1:
            mask = jnp.broadcast_to(mask[None, :], (n, p))
    bn = block_n or _pick_block_padded(n, _targets("stage")[0])
    n_pad = _pad_dim(n, bn)
    if n_pad != n:
        x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
        mask = jnp.pad(mask, ((0, n_pad - n), (0, 0)))
    z, t2, spe = _pca_monitor(x, w, mean2d, invlam2d, mask, bn,
                              _auto_interpret(interpret))
    return z[:n], t2[:n, 0], spe[:n, 0]


def pca_monitor_batched(x: jnp.ndarray, w: jnp.ndarray,
                        mean: jnp.ndarray | None = None,
                        inv_lam: jnp.ndarray | None = None,
                        *, mask: jnp.ndarray | None = None,
                        block_n: int | None = None,
                        interpret: bool | None = None,
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fleet form of :func:`pca_monitor` over x (B, n, p).

    ``w`` is (B, p, q) per-network bases (or (p, q) shared), ``mean``
    (B, p) / (p,) / None, ``inv_lam`` (B, q) / (q,) / None, ``mask``
    (B, n, p) / (B, p) / None.  A ``vmap`` of the fused kernel, same
    composition as :func:`supervised_compress_batched`.
    """
    if x.ndim != 3:
        raise ValueError(f"expected (networks, n, p), got {x.shape}")
    B, n, p = x.shape
    if w.ndim == 2:
        w = jnp.broadcast_to(w[None], (B,) + w.shape)
    q = w.shape[2]
    if mean is None:
        mean = jnp.zeros((B, p), jnp.float32)
    else:
        mean = jnp.asarray(mean, jnp.float32)
        if mean.ndim == 1:
            mean = jnp.broadcast_to(mean[None, :], (B, p))
    if inv_lam is None:
        inv_lam = jnp.ones((B, q), jnp.float32)
    else:
        inv_lam = jnp.asarray(inv_lam, jnp.float32)
        if inv_lam.ndim == 1:
            inv_lam = jnp.broadcast_to(inv_lam[None, :], (B, q))
    if mask is None:
        mask = jnp.ones((B, n, p), jnp.float32)
    else:
        mask = jnp.asarray(mask, jnp.float32)
        if mask.ndim == 2:
            mask = jnp.broadcast_to(mask[:, None, :], (B, n, p))
    return jax.vmap(
        lambda xi, wi, mi, li, ki: pca_monitor(
            xi, wi, mi, li, mask=ki, block_n=block_n,
            interpret=interpret))(x, w, mean, inv_lam, mask)


def supervised_compress_batched(x: jnp.ndarray, w: jnp.ndarray,
                                mean: jnp.ndarray | None = None,
                                *, epsilon: float,
                                mask: jnp.ndarray | None = None,
                                block_n: int | None = None,
                                interpret: bool | None = None,
                                ) -> tuple[jnp.ndarray, jnp.ndarray,
                                           jnp.ndarray]:
    """Fleet form of :func:`supervised_compress` over x (B, n, p).

    ``w`` is (B, p, q) per-network bases (or (p, q) shared), ``mean``
    (B, p) / (p,) / None, ``mask`` (B, n, p) / (B, p) / None.  A ``vmap``
    of the fused kernel: Pallas turns the networks axis into an extra
    outer grid axis, keeping the per-network tiling identical.
    """
    if x.ndim != 3:
        raise ValueError(f"expected (networks, n, p), got {x.shape}")
    B, n, p = x.shape
    if w.ndim == 2:
        w = jnp.broadcast_to(w[None], (B,) + w.shape)
    if mean is None:
        mean = jnp.zeros((B, p), jnp.float32)
    else:
        mean = jnp.asarray(mean, jnp.float32)
        if mean.ndim == 1:
            mean = jnp.broadcast_to(mean[None, :], (B, p))
    if mask is None:
        mask = jnp.ones((B, n, p), jnp.float32)
    else:
        mask = jnp.asarray(mask, jnp.float32)
        if mask.ndim == 2:
            mask = jnp.broadcast_to(mask[:, None, :], (B, n, p))
    return jax.vmap(
        lambda xi, wi, mi, ki: supervised_compress(
            xi, wi, mi, epsilon=epsilon, mask=ki, block_n=block_n,
            interpret=interpret))(x, w, mean, mask)


@functools.partial(jax.jit,
                   static_argnames=("halfwidth", "epsilon", "with_compress",
                                    "with_monitor", "block_p", "block_n",
                                    "interpret"))
def _fused_stream(x, mask, w_rows, basis, mean2d, invlam2d, halfwidth,
                  epsilon, with_compress, with_monitor, block_p, block_n,
                  interpret):
    h = halfwidth
    xpad = jnp.pad(x, ((0, 0), (h, h)))
    mpad = jnp.pad(mask, ((0, 0), (h, h)))
    return fused_stream_pallas(
        x, xpad, mask, mpad, w_rows, basis, mean2d, invlam2d,
        halfwidth=h, epsilon=epsilon, with_compress=with_compress,
        with_monitor=with_monitor, block_p=block_p, block_n=block_n,
        interpret=interpret)


def _fused_prep(x, basis, mean, inv_lam, mask, precision):
    """Shared operand normalization of the fused wrapper and its blocked
    jnp twin: fp32 canonical forms, ones mask default, optional bf16
    downcast of the LARGE operands only (x/mask/basis — the tile traffic;
    mean, inv_lam and the row weights are replicated scalars/rows and stay
    fp32, as do every in-kernel accumulator and every output)."""
    rows, p = x.shape
    q = basis.shape[1]
    x = jnp.asarray(x, jnp.float32)
    mean2d = (jnp.zeros((1, p), jnp.float32) if mean is None
              else jnp.asarray(mean, jnp.float32).reshape(1, p))
    invlam2d = (jnp.ones((1, q), jnp.float32) if inv_lam is None
                else jnp.asarray(inv_lam, jnp.float32).reshape(1, q))
    if mask is None:
        mask = jnp.ones((rows, p), jnp.float32)
    else:
        mask = jnp.asarray(mask, jnp.float32)
        if mask.ndim == 1:
            mask = jnp.broadcast_to(mask[None, :], (rows, p))
    basis = jnp.asarray(basis, jnp.float32)
    if precision not in ("fp32", "bf16"):
        raise ValueError(f"precision must be 'fp32' or 'bf16', "
                         f"got {precision!r}")
    if precision == "bf16":
        x = x.astype(jnp.bfloat16)
        mask = mask.astype(jnp.bfloat16)     # 0/1: exact in bf16
        basis = basis.astype(jnp.bfloat16)
    return x, mask, basis, mean2d, invlam2d


def fused_stream_update(x: jnp.ndarray, weights: jnp.ndarray,
                        basis: jnp.ndarray,
                        mean: jnp.ndarray | None = None,
                        inv_lam: jnp.ndarray | None = None, *,
                        halfwidth: int, epsilon: float = 0.0,
                        with_compress: bool, with_monitor: bool,
                        mask: jnp.ndarray | None = None,
                        precision: str = "fp32",
                        block_p: int | None = None,
                        block_n: int | None = None,
                        interpret: bool | None = None,
                        ) -> tuple[jnp.ndarray, jnp.ndarray,
                                   jnp.ndarray | None, jnp.ndarray | None,
                                   jnp.ndarray | None, jnp.ndarray | None]:
    """ONE kernel pass over a flattened (rows, p) chunk: the forgetting-
    weighted band fold plus the configured per-row stages
    (:func:`repro.kernels.fused_stream.fused_stream_pallas`).

    ``weights`` (rows,) carries each row's round weight (γ^(live after)
    with 0 for pad/invalid rows); ``mask`` the per-row 0/1 validity
    ((rows, p), (p,) broadcast, or None = all live).  ``basis`` (p, q),
    ``mean`` (p,) and ``inv_lam`` (q,) are the stage operands.

    Returns ``(band, z, x_hat, flagged, t2, spe)`` — band (2h+1, p) fp32;
    z (rows, q); x_hat (rows, p) and bool ``flagged`` (compression, else
    None); t2/spe (rows,) (monitoring, else None).  With fp32 operands
    the band is bit-identical to :func:`cov_band_update_chunk` at the
    same blocks and the stages to :func:`supervised_compress` /
    :func:`pca_monitor`; ``precision="bf16"`` downcasts the tile-load
    operands (x, mask, basis) to bfloat16 — halving the chunk's HBM
    traffic — while every accumulator and output stays fp32.

    The row axis is padded to the block with zero-weight zero-mask rows
    (exact no-ops everywhere), an awkward feature axis is zero-padded to
    the band's feature block exactly like :func:`cov_band_update_chunk`
    (the stage dots stay at the exact width — the kernel re-slices the
    halo slab), and every output is sliced back.
    """
    rows, p = x.shape
    x, mask, basis, mean2d, invlam2d = _fused_prep(
        x, basis, mean, inv_lam, mask, precision)
    weights = jnp.asarray(weights, jnp.float32).reshape(rows, 1)
    plan = kernel_block_plan("fused", rows=rows, p=p, dtype=precision)
    bp = block_p or plan["block_p"]
    bn = block_n or plan["block_n"]
    rows_pad = _pad_dim(rows, bn)
    p_pad = _pad_dim(p, bp)
    if (rows_pad, p_pad) != (rows, p):
        x = jnp.pad(x, ((0, rows_pad - rows), (0, p_pad - p)))
        mask = jnp.pad(mask, ((0, rows_pad - rows), (0, p_pad - p)))
        weights = jnp.pad(weights, ((0, rows_pad - rows), (0, 0)))
    out = _fused_stream(x, mask, weights, basis, mean2d, invlam2d,
                        halfwidth, float(epsilon), with_compress,
                        with_monitor, bp, bn, _auto_interpret(interpret))
    band, z = out[0][:, :p], out[1][:rows]
    i = 2
    x_hat = flagged = t2 = spe = None
    if with_compress:
        x_hat = out[i][:rows]
        flagged = out[i + 1][:rows] > 0.0
        i += 2
    if with_monitor:
        t2 = out[i][:rows, 0]
        spe = out[i + 1][:rows, 0]
    return band, z, x_hat, flagged, t2, spe


def fused_stream_stages_blocked(x: jnp.ndarray, basis: jnp.ndarray,
                                mean: jnp.ndarray | None = None,
                                inv_lam: jnp.ndarray | None = None, *,
                                epsilon: float = 0.0,
                                with_compress: bool, with_monitor: bool,
                                mask: jnp.ndarray | None = None,
                                precision: str = "fp32",
                                block_n: int | None = None,
                                ) -> tuple[jnp.ndarray,
                                           jnp.ndarray | None,
                                           jnp.ndarray | None,
                                           jnp.ndarray | None,
                                           jnp.ndarray | None]:
    """The fused kernel's STAGE arithmetic as a plain-jnp scan over row
    blocks — same tile shapes, same op order, same fp32 accumulation as
    the kernel body, and therefore (in interpret mode) the same bits.
    A ``lax.scan`` (not an unrolled python loop) mirrors the interpret
    grid loop structurally: unrolling lets XLA fuse across blocks and
    re-vectorize the SPE reduction, which drifts bits at multi-block
    shapes.

    This is the post-refresh fix-up of the fused driver path
    (:func:`repro.streaming.driver.chunk_stream_step`): the kernel runs
    ONCE against the pre-decision basis; when the scheduler then rotates
    W, the stages must be re-evaluated against the post-decision basis —
    re-launching the kernel would double the chunk's HBM traffic on every
    refresh AND put a second ``pallas_call`` into the traced chunk body
    (the jaxpr launch-count guarantee counts both ``lax.cond`` branches).
    A pure-jnp twin recomputes only the MXU/VPU stage math (no band fold —
    the fold is basis-independent) with identical per-block shapes.

    Returns ``(z, x_hat, flagged, t2, spe)`` with None for disabled
    stages, like :func:`fused_stream_update` minus the band.
    """
    rows, p = x.shape
    x, mask, basis, mean2d, invlam2d = _fused_prep(
        x, basis, mean, inv_lam, mask, precision)
    bn = block_n or _pick_block_padded(rows, _targets("fused", precision)[0])
    rows_pad = _pad_dim(rows, bn)
    if rows_pad != rows:
        x = jnp.pad(x, ((0, rows_pad - rows), (0, 0)))
        mask = jnp.pad(mask, ((0, rows_pad - rows), (0, 0)))
    w = basis.astype(jnp.float32)
    nblk = rows_pad // bn

    def _block(_, xm):
        xb, mb = xm
        xb = xb.astype(jnp.float32)
        mb = mb.astype(jnp.float32)
        xc = (xb - mean2d) * mb
        z = jnp.dot(xc, w, preferred_element_type=jnp.float32)
        xh_r = jnp.dot(z, w.T, preferred_element_type=jnp.float32)
        if with_compress:
            xh = xh_r + mean2d
            fl = (jnp.abs(xb - xh) > epsilon) & (mb > 0.0)
        else:
            xh = fl = jnp.zeros((), jnp.float32)
        if with_monitor:
            resid = (xc - xh_r) * mb
            t2 = jnp.sum(z * z * invlam2d, axis=1)
            spe = jnp.sum(resid * resid, axis=1)
        else:
            t2 = spe = jnp.zeros((), jnp.float32)
        return None, (z, xh, fl, t2, spe)

    _, (z, xh, fl, t2, spe) = jax.lax.scan(
        _block, None, (x.reshape(nblk, bn, p), mask.reshape(nblk, bn, p)))
    flat = lambda a: a.reshape((rows_pad,) + a.shape[2:])[:rows]
    return (flat(z),
            flat(xh) if with_compress else None,
            flat(fl) if with_compress else None,
            flat(t2) if with_monitor else None,
            flat(spe) if with_monitor else None)
