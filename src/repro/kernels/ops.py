"""Jitted public wrappers around the Pallas kernels.

Handles padding, block-size selection, dtype promotion and the
interpret-mode fallback (this container is CPU-only: ``interpret=True``
executes the kernel bodies in Python for correctness validation; on real TPU
the same code path compiles to Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.banded_matvec import banded_matvec_pallas, banded_matmul_pallas
from repro.kernels.cov_update import (cov_band_update_pallas,
                                      cov_band_update_masked_pallas)
from repro.kernels.pca_project import pca_project_pallas, pca_reconstruct_pallas

__all__ = ["banded_matvec", "banded_matmul", "cov_band_update",
           "cov_band_update_masked", "cov_band_update_batched",
           "pca_project", "pca_reconstruct"]


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pick_block(p: int, target: int = 512) -> int:
    """Largest divisor of p that is <= target (prefers multiples of 128)."""
    for cand in (target, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= target and p % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def _banded_matvec(band, v, block_p, interpret):
    nb = band.shape[0]
    h = (nb - 1) // 2
    vpad = jnp.pad(v, (h, h)).reshape(1, -1)
    out = banded_matvec_pallas(band, vpad, block_p=block_p, interpret=interpret)
    return out[0]


def banded_matvec(band: jnp.ndarray, v: jnp.ndarray,
                  block_p: int | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """y = C v with C banded (2h+1, p) diagonals; v (p,)."""
    nb, p = band.shape
    bp = block_p or _pick_block(p)
    return _banded_matvec(band, v, bp, _auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def _banded_matmul(band, V, block_p, interpret):
    nb = band.shape[0]
    h = (nb - 1) // 2
    vpad = jnp.pad(V, ((h, h), (0, 0)))
    return banded_matmul_pallas(band, vpad, block_p=block_p, interpret=interpret)


def banded_matmul(band: jnp.ndarray, V: jnp.ndarray,
                  block_p: int | None = None,
                  interpret: bool | None = None) -> jnp.ndarray:
    """Y = C V with C banded; V (p, q)."""
    nb, p = band.shape
    bp = block_p or _pick_block(p)
    return _banded_matmul(band, V, bp, _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("halfwidth", "block_p", "block_n",
                                    "interpret"))
def _cov_band_update(x, halfwidth, block_p, block_n, interpret):
    h = halfwidth
    xpad = jnp.pad(x, ((0, 0), (h, h)))
    return cov_band_update_pallas(x, xpad, halfwidth=h, block_p=block_p,
                                  block_n=block_n, interpret=interpret)


def cov_band_update(x: jnp.ndarray, halfwidth: int,
                    block_p: int | None = None, block_n: int | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """delta band (2h+1, p) = sum_t outer(x_t, x_t) restricted to the band."""
    n, p = x.shape
    bp = block_p or _pick_block(p)
    bn = block_n or _pick_block(n, target=128)
    return _cov_band_update(x, halfwidth, bp, bn, _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("halfwidth", "block_p", "block_n",
                                    "interpret"))
def _cov_band_update_masked(x, mask, halfwidth, block_p, block_n, interpret):
    h = halfwidth
    xpad = jnp.pad(x, ((0, 0), (h, h)))
    mpad = jnp.pad(mask, ((0, 0), (h, h)))
    return cov_band_update_masked_pallas(x, xpad, mask, mpad, halfwidth=h,
                                         block_p=block_p, block_n=block_n,
                                         interpret=interpret)


def cov_band_update_masked(x: jnp.ndarray, mask: jnp.ndarray, halfwidth: int,
                           block_p: int | None = None,
                           block_n: int | None = None,
                           interpret: bool | None = None) -> jnp.ndarray:
    """Masked delta band: products where either entry is masked contribute 0.

    ``mask`` is a 0/1 validity array, either (p,) — a sensor-liveness mask
    broadcast over the batch (dead motes) — or (n, p) for per-reading
    measurement dropout.  The multiply is fused into the kernel's tile
    loads: no masked copy of ``x`` is materialized in HBM, though the mask
    itself streams alongside ``x`` (a (p,) mask is broadcast to the batch
    shape first, so the masked update reads roughly twice the input bytes
    of the unmasked kernel — acceptable for a VPU-bound kernel, and the
    ``mask=None`` fast path in callers keeps the fault-free fleet at
    unmasked cost).
    """
    n, p = x.shape
    mask = jnp.asarray(mask, dtype=x.dtype)
    if mask.ndim == 1:
        mask = jnp.broadcast_to(mask[None, :], (n, p))
    if mask.shape != (n, p):
        raise ValueError(f"mask shape {mask.shape} incompatible with {(n, p)}")
    bp = block_p or _pick_block(p)
    bn = block_n or _pick_block(n, target=128)
    return _cov_band_update_masked(x, mask, halfwidth, bp, bn,
                                   _auto_interpret(interpret))


def cov_band_update_batched(x: jnp.ndarray, halfwidth: int,
                            block_p: int | None = None,
                            block_n: int | None = None,
                            interpret: bool | None = None) -> jnp.ndarray:
    """Per-network delta bands (B, 2h+1, p) from a fleet batch x (B, n, p).

    The networks axis is independent (no cross-network products), so the
    batched form is a ``vmap`` of the single-network kernel: Pallas turns the
    batch dimension into an extra outer grid axis, keeping the per-network
    tiling identical to :func:`cov_band_update`.  The streaming fleet driver
    reaches the same composition implicitly (``vmap`` over
    ``online_update``); this explicit wrapper is for callers that hold a
    (networks, n, p) block outside the driver — fleet-wide preprocessing,
    benchmarks, ad-hoc analysis.
    """
    if x.ndim != 3:
        raise ValueError(f"expected (networks, n, p), got {x.shape}")
    _, n, p = x.shape
    bp = block_p or _pick_block(p)
    bn = block_n or _pick_block(n, target=128)
    itp = _auto_interpret(interpret)
    return jax.vmap(
        lambda xi: _cov_band_update(xi, halfwidth, bp, bn, itp))(x)


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def _pca_project(x, w, block_n, block_k, interpret):
    return pca_project_pallas(x, w, block_n=block_n, block_k=block_k,
                              interpret=interpret)


def pca_project(x: jnp.ndarray, w: jnp.ndarray,
                block_n: int | None = None, block_k: int | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """Z = X W (PCAg scores for a batch of rows)."""
    n, p = x.shape
    bn = block_n or _pick_block(n, target=128)
    bk = block_k or _pick_block(p)
    return _pca_project(x, w, bn, bk, _auto_interpret(interpret))


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_p", "interpret"))
def _pca_reconstruct(z, w, block_n, block_p, interpret):
    return pca_reconstruct_pallas(z, w, block_n=block_n, block_p=block_p,
                                  interpret=interpret)


def pca_reconstruct(z: jnp.ndarray, w: jnp.ndarray,
                    block_n: int | None = None, block_p: int | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """X_hat = Z W^T."""
    n, q = z.shape
    p = w.shape[0]
    bn = block_n or _pick_block(n, target=128)
    bp = block_p or _pick_block(p)
    return _pca_reconstruct(z, w, bn, bp, _auto_interpret(interpret))
